"""FusedExecutor: the whole Biathlon feedback loop as ONE XLA program.

Beyond-paper TPU adaptation (DESIGN.md §2): the HostLoopExecutor mirrors the
paper — a Python loop dispatching AFC / AMI / Planner stages per iteration,
paying a host<->device round trip + dispatch latency every cycle.  Once the
datastore I/O is approximated away, those round trips dominate single-digit-
millisecond serving budgets.

The fused variant expresses the iterate-until-guaranteed loop as a
``jax.lax.while_loop`` over fixed-shape state:

* sample growth is a *monotone prefix mask* over pre-gathered, pre-permuted
  (k, cap) buffers — the plan z is data, not shape;
* AFC = masked-moment estimators (the sampled_agg kernel's math);
* AMI + Sobol indices reuse one fused QMC evaluation batch of
  m x (k + 2) rows per iteration;
* the loop condition is the Eq. 1 guarantee check.

Restrictions vs the host loop (documented): parametric aggregates only
(SUM/COUNT/AVG/VAR/STD — bootstrap resampling for MEDIAN needs per-iteration
RNG shapes that stay host-side), and the per-request buffer is capped at
``cap`` rows (the guarantee's worst case degrades to exact-over-cap).
Batched serving vmaps this executor over concurrent requests.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.planner import direction, next_plan
from repro.core.propagation import qmc_uniforms
from repro.core.qmc import uniform_to_normal

f32 = jnp.float32

__all__ = ["FusedResult", "build_fused_executor"]


class FusedResult(NamedTuple):
    y_hat: jnp.ndarray
    prob: jnp.ndarray
    iters: jnp.ndarray
    z: jnp.ndarray          # (k,) final plan
    samples_used: jnp.ndarray


from repro.data.aggregates import masked_estimates_batch as _masked_estimates  # noqa: E402


def build_fused_executor(
    model_fn,
    *,
    k: int,
    task: str,
    n_classes: int = 2,
    m: int = 512,
    m_sobol: int = 128,
    alpha: float = 0.05,
    gamma: float = 0.01,
    tau: float = 0.95,
    max_iters: int = 32,
):
    """Returns jit-able ``run(vals (k,cap), n (k,), agg_ids (k,), delta) -> FusedResult``.

    ``model_fn``: (rows (n,k), exact (e,)) -> (n,) predictions (regression
    values or class ids); must be jittable — tabular models and LM heads both
    qualify.  ``exact`` carries the request's exactly-computed features so a
    single compiled executor serves every request of the pipeline.
    """

    u_ami = qmc_uniforms(m, k)                       # (m, k) static
    u_sob = qmc_uniforms(m_sobol, 2 * k, None)       # (m_sobol, 2k)

    def sample_rows(value, sigma, u):
        return value[None, :] + sigma[None, :] * uniform_to_normal(u)

    def ami(value, sigma, exact):
        x = sample_rows(value, sigma, u_ami)
        y = model_fn(x, exact).astype(f32)
        y_hat = model_fn(value[None, :], exact).astype(f32).reshape(())
        if task == "regression":
            y_bar = jnp.mean(y)
            sd = jnp.sqrt(jnp.mean((y - y_bar) ** 2))
            return y_hat, y_bar, sd
        probs = jnp.bincount(y.astype(jnp.int32), length=n_classes).astype(f32) / m
        return y_hat, probs[y_hat.astype(jnp.int32)], jnp.zeros((), f32)

    def guarantee_prob(y_hat, mean, sd, delta):
        if task == "classification":
            return mean
        bias = mean - y_hat
        safe = jnp.maximum(sd, 1e-12)
        phi = jax.scipy.stats.norm.cdf
        prob = phi((delta - bias) / safe) - phi((-delta - bias) / safe)
        return jnp.where(sd <= 1e-12, (jnp.abs(bias) <= delta).astype(f32), prob)

    def sobol_indices(value, sigma, y_hat, exact):
        ua, ub = u_sob[:, :k], u_sob[:, k:]
        xa = sample_rows(value, sigma, ua)
        xb = sample_rows(value, sigma, ub)
        eye = jnp.eye(k, dtype=bool)
        xab = jnp.where(eye[:, None, :], xb[None], xa[None]).reshape(k * m_sobol, k)
        f_all = model_fn(jnp.concatenate([xa, xb, xab], 0), exact).astype(f32)
        if task == "classification":
            f_all = (f_all.astype(jnp.int32) == y_hat.astype(jnp.int32)).astype(f32)
        f_all = f_all - jnp.mean(f_all)  # center (see sobol_indices.py)
        fa, fb = f_all[:m_sobol], f_all[m_sobol : 2 * m_sobol]
        fab = f_all[2 * m_sobol :].reshape(k, m_sobol)
        var_y = jnp.var(f_all)
        v_j = jnp.mean(fb[None] * (fab - fa[None]), axis=1)
        return jnp.where(var_y > 1e-12, jnp.clip(v_j / jnp.maximum(var_y, 1e-12), 0, 1), 0.0)

    @jax.jit
    def run(vals, n, agg_ids, delta, exact) -> FusedResult:
        cap = vals.shape[1]
        n = jnp.minimum(n.astype(jnp.int32), cap)
        z0 = jnp.clip(
            jnp.ceil(alpha * n.astype(f32)).astype(jnp.int32), jnp.minimum(2, n), n
        )
        step = jnp.maximum(
            jnp.ceil(gamma * jnp.sum(n).astype(f32)).astype(jnp.int32), 1
        )

        def evaluate(z):
            value, sigma = _masked_estimates(vals, z, n, agg_ids)
            y_hat, mean, sd = ami(value, sigma, exact)
            prob = guarantee_prob(y_hat, mean, sd, delta)
            return value, sigma, y_hat, prob

        def cond(state):
            z, it, y_hat, prob = state
            return (prob < tau) & (it < max_iters) & jnp.any(z < n)

        def body(state):
            z, it, _, _ = state
            value, sigma, y_hat, _ = evaluate(z)
            idx = sobol_indices(value, sigma, y_hat, exact)
            d = direction(idx, z, n)
            z = next_plan(z, d, step, n)
            _, _, y_hat, prob = evaluate(z)
            return (z, it + 1, y_hat, prob)

        _, _, y_hat0, prob0 = evaluate(z0)
        z, iters, y_hat, prob = jax.lax.while_loop(
            cond, body, (z0, jnp.zeros((), jnp.int32), y_hat0, prob0)
        )
        return FusedResult(
            y_hat=y_hat,
            prob=prob,
            iters=iters,
            z=z,
            samples_used=jnp.sum(jnp.minimum(z, n)),
        )

    return run
