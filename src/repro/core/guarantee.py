"""The accuracy guarantee check (paper Eq. 1).

    Pr(|Y − ŷ| ≤ δ) ≥ τ

Regression:  U_y ~ N(ȳ − ŷ, σ_y²), so
    Pr = Φ((δ − (ȳ−ŷ)) / σ_y) − Φ((−δ − (ȳ−ŷ)) / σ_y).
Classification (δ must be 0):  U_y ~ Bernoulli(1 − p_ŷ), so
    Pr = p_ŷ.

Degenerate σ_y = 0 (all features exact, or the model is flat in the sampled
region) means Y is deterministic at ȳ: Pr = 1[|ȳ − ŷ| ≤ δ].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.propagation import InferenceUncertainty

__all__ = ["regression_prob", "classification_prob", "satisfied"]

_Phi = jax.scipy.stats.norm.cdf


def regression_prob(u: InferenceUncertainty, delta: jnp.ndarray) -> jnp.ndarray:
    """Pr(|Y − ŷ| ≤ δ) for a Normal inference-uncertainty model."""
    bias = u.mean - u.y_hat
    sigma = u.std
    safe = jnp.maximum(sigma, 1e-12)
    prob = _Phi((delta - bias) / safe) - _Phi((-delta - bias) / safe)
    exact = (jnp.abs(bias) <= delta).astype(prob.dtype)
    return jnp.where(sigma <= 1e-12, exact, prob)


def classification_prob(u: InferenceUncertainty) -> jnp.ndarray:
    """Pr(Y == ŷ) = p_ŷ for the Categorical inference-uncertainty model."""
    return u.mean


def satisfied(
    u: InferenceUncertainty,
    delta: float | jnp.ndarray,
    tau: float,
    task: str,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (prob, ok) for Eq. 1; ``task`` in {"regression","classification"}."""
    if task == "regression":
        prob = regression_prob(u, jnp.asarray(delta, jnp.float32))
    elif task == "classification":
        prob = classification_prob(u)
    else:  # pragma: no cover - config error
        raise ValueError(f"unknown task {task!r}")
    return prob, prob >= tau
